"""State-space / recurrent sequence mixers: Mamba2 (SSD), mLSTM, sLSTM.

All three expose a chunked/parallel *train* form and an O(1)-state *decode*
form — these are the sub-quadratic archs that run the ``long_500k`` cells.

Mamba2 follows the SSD chunked decomposition (intra-chunk quadratic term +
inter-chunk recurrent state), adapted to TPU as einsums over MXU-friendly
chunk sizes.  mLSTM is the xLSTM matrix-memory cell in its stabilized
chunk-parallel form; sLSTM is the scalar-memory cell with recurrent gate
connections — inherently sequential, implemented as a time scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import core as scalpel
from repro.dist.partition import shard
from .params import P
from .spec import ModelConfig


# ---------------------------------------------------------------------------
# depthwise causal conv1d (shared by mamba2 / mLSTM branches)
# ---------------------------------------------------------------------------

def causal_conv1d(x, w, state=None, length=None):
    """x: [b,s,c], w: [k,c] depthwise. Returns (y, new_state [b,k-1,c]).

    ``length`` (traced i32, None => s): with right-padded input, the carried
    state must be the last k-1 REAL positions — the window ending at
    ``length``, not at the pad tail.
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    if k <= 1:
        new_state = state
    elif length is None:
        new_state = xp[:, -(k - 1):, :]
    else:
        # xp index i holds x index i-(k-1): the k-1 inputs preceding
        # position ``length`` live at xp[length : length + k-1]
        new_state = jax.lax.dynamic_slice_in_dim(xp, length, k - 1, axis=1)
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------

def mamba2_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.ssm.expand * d
    hd = cfg.ssm.head_dim
    nh = di // hd
    N = cfg.ssm.d_state
    kw = cfg.ssm.d_conv
    conv_ch = di + 2 * N  # x + B + C go through the conv
    return {
        "in_proj": P((d, 2 * di + 2 * N + nh),
                     ("embed", "heads")),  # z | x | B | C | dt
        "conv_w": P((kw, conv_ch), ("conv", None), scale=0.5),
        "conv_b": P((conv_ch,), (None,), init="zeros"),
        "A_log": P((nh,), (None,), init="zeros", scale=1.0),
        "dt_bias": P((nh,), (None,), init="zeros"),
        "D": P((nh,), (None,), init="ones"),
        "norm": P((di,), ("heads",), init="ones"),
        "out_proj": P((di, d), ("heads", "embed")),
    }


def _ssd_chunked(xh, dt, da_log, B, C, S0=None, chunk=256):
    """SSD scan. xh:[b,s,h,p] dt:[b,s,h] da_log:[b,s,h] (log decay per step)
    B,C: [b,s,N].  Returns (y [b,s,h,p], S_final [b,h,p,N])."""
    b, s, h, p = xh.shape
    N = B.shape[-1]
    Q = min(chunk, s)
    if s % Q:
        # pad to a chunk multiple with identity steps (dt=0, da_log=0 keeps
        # the state; padded y rows are sliced off below)
        pad = Q - s % Q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        da_log = jnp.pad(da_log, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        y, Sf = _ssd_chunked(xh, dt, da_log, B, C, S0=S0, chunk=Q)
        return y[:, :s], Sf
    nc = s // Q
    xc = xh.reshape(b, nc, Q, h, p)
    dtc = dt.reshape(b, nc, Q, h)
    alc = da_log.reshape(b, nc, Q, h)
    Bc = B.reshape(b, nc, Q, N)
    Cc = C.reshape(b, nc, Q, N)

    def chunk_step(S, inp):
        xq, dtq, alq, Bq, Cq = inp  # [b,Q,...]
        cum = jnp.cumsum(alq, axis=1)  # [b,Q,h] log decay from chunk start
        total = cum[:, -1]  # [b,h]
        # intra-chunk: L[i,j] = exp(cum_i - cum_j) for j<=i
        li = cum[:, :, None, :] - cum[:, None, :, :]  # [b,Q,Q,h]
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.where(mask[None, :, :, None], jnp.exp(li), 0.0)
        G = jnp.einsum("bin,bjn->bij", Cq, Bq)  # [b,Q,Q]
        M = G[..., None] * L * dtq[:, None, :, :]  # [b,i,j,h]
        y_intra = jnp.einsum("bijh,bjhp->bihp", M.astype(xq.dtype), xq)
        # inter-chunk: contribution of incoming state
        decay_in = jnp.exp(cum)  # [b,Q,h]
        y_inter = jnp.einsum(
            "bin,bhpn,bih->bihp", Cq.astype(jnp.float32),
            S.astype(jnp.float32), decay_in,
        ).astype(xq.dtype)
        # state update: S' = S*exp(total) + sum_j exp(total-cum_j) dt_j B_j x_j
        w = jnp.exp(total[:, None, :] - cum) * dtq  # [b,Q,h]
        dS = jnp.einsum(
            "bjn,bjhp,bjh->bhpn", Bq.astype(jnp.float32),
            xq.astype(jnp.float32), w,
        )
        S2 = S * jnp.exp(total)[:, :, None, None] + dS
        return S2, y_intra + y_inter

    S0 = (jnp.zeros((b, h, p, N), jnp.float32) if S0 is None else S0)
    inputs = (
        xc.transpose(1, 0, 2, 3, 4),
        dtc.transpose(1, 0, 2, 3),
        alc.transpose(1, 0, 2, 3),
        Bc.transpose(1, 0, 2, 3),
        Cc.transpose(1, 0, 2, 3),
    )
    Sf, ys = jax.lax.scan(chunk_step, S0, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y, Sf


def _mamba2_project(cfg: ModelConfig, p, x):
    d = cfg.d_model
    di = cfg.ssm.expand * d
    N = cfg.ssm.d_state
    hd = cfg.ssm.head_dim
    nh = di // hd
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xi, B, C, dtp = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1
    )
    return z, xi, B, C, dtp, di, N, hd, nh


def mamba2(cfg: ModelConfig, p, x, state=None, conv_state=None):
    """Full-sequence Mamba2 mixer. x: [b,s,d] -> (y, (S, conv_state))."""
    with scalpel.function("ssm"):
        b, s, d = x.shape
        z, xi, B, C, dtp, di, N, hd, nh = _mamba2_project(cfg, p, x)
        xbc = jnp.concatenate([xi, B, C], axis=-1)
        xbc, conv_state = causal_conv1d(
            xbc, p["conv_w"].astype(x.dtype), conv_state
        )
        xbc = jax.nn.silu(
            (xbc + p["conv_b"].astype(x.dtype)).astype(jnp.float32)
        ).astype(x.dtype)
        xi, B, C = jnp.split(xbc, [di, di + N], axis=-1)
        dt = jax.nn.softplus(
            dtp.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
        )  # [b,s,nh]
        A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [nh] negative
        da_log = dt * A[None, None, :]
        xh = xi.reshape(b, s, nh, hd)
        xh = shard(xh, "batch", None, "heads", None)
        y, S = _ssd_chunked(xh, dt, da_log, B, C, S0=state,
                            chunk=cfg.ssm.chunk)
        scalpel.probe(state=S)
        y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
        y = y.reshape(b, s, di)
        # gated RMSNorm (mamba2 style)
        from .layers import rms_norm

        y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                     p["norm"])
        out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
        out = shard(out, "batch", None, None)
        scalpel.probe(out=out)
        return out, (S, conv_state)


def mamba2_decode(cfg: ModelConfig, p, x, state, conv_state):
    """One-token decode. x: [b,1,d]; state [b,h,p,N]; conv [b,k-1,ch]."""
    with scalpel.function("ssm"):
        b = x.shape[0]
        z, xi, B, C, dtp, di, N, hd, nh = _mamba2_project(cfg, p, x)
        xbc = jnp.concatenate([xi, B, C], axis=-1)
        xbc, conv_state = causal_conv1d(
            xbc, p["conv_w"].astype(x.dtype), conv_state
        )
        xbc = jax.nn.silu(
            (xbc + p["conv_b"].astype(x.dtype)).astype(jnp.float32)
        ).astype(x.dtype)
        xi, B, C = jnp.split(xbc, [di, di + N], axis=-1)
        dt = jax.nn.softplus(
            dtp.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
        )[:, 0]  # [b,nh]
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        da = jnp.exp(dt * A[None, :])  # [b,nh]
        xh = xi.reshape(b, nh, hd)
        Bq = B[:, 0]  # [b,N]
        Cq = C[:, 0]
        state = state * da[:, :, None, None] + jnp.einsum(
            "bn,bhp,bh->bhpn", Bq.astype(jnp.float32),
            xh.astype(jnp.float32), dt,
        )
        scalpel.probe(state=state)
        y = jnp.einsum(
            "bn,bhpn->bhp", Cq.astype(jnp.float32), state
        ).astype(x.dtype)
        y = y + xh * p["D"].astype(x.dtype)[None, :, None]
        y = y.reshape(b, 1, di)
        from .layers import rms_norm

        y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                     p["norm"])
        out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
        scalpel.probe(out=out)
        return out, (state, conv_state)


def mamba2_state_specs(cfg: ModelConfig, batch: int):
    di = cfg.ssm.expand * cfg.d_model
    nh = di // cfg.ssm.head_dim
    conv_ch = di + 2 * cfg.ssm.d_state
    return {
        "ssm": jax.ShapeDtypeStruct(
            (batch, nh, cfg.ssm.head_dim, cfg.ssm.d_state), jnp.float32
        ),
        "conv": jax.ShapeDtypeStruct(
            (batch, cfg.ssm.d_conv - 1, conv_ch), jnp.dtype(cfg.compute_dtype)
        ),
    }


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory cell, chunk-parallel stabilized form)
# ---------------------------------------------------------------------------

def mlstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = 2 * d  # xLSTM proj factor 2
    nh = cfg.n_heads
    kw = cfg.ssm.d_conv
    return {
        "up": P((d, 2 * di), ("embed", "heads")),       # x | z
        "conv_w": P((kw, di), ("conv", None), scale=0.5),
        "conv_b": P((di,), (None,), init="zeros"),
        "wq": P((di, di), ("heads", "heads")),
        "wk": P((di, di), ("heads", "heads")),
        "wv": P((di, di), ("heads", "heads")),
        "w_if": P((di, 2 * nh), ("heads", None), scale=0.02),
        "b_if": P((2 * nh,), (None,), init="zeros"),
        "norm": P((di,), ("heads",), init="ones"),
        "down": P((di, d), ("heads", "embed")),
        "skip": P((di,), (None,), init="ones"),
    }


def _mlstm_chunked(q, k, v, log_i, log_f, chunk, C0=None, n0=None, m0=None):
    """Stabilized chunkwise mLSTM.  q,k,v: [b,s,h,p]; log_i/log_f: [b,s,h].
    Returns (h [b,s,h,p], (C [b,h,p,p], n [b,h,p], m [b,h]))."""
    b, s, h, p = q.shape
    Q = min(chunk, s)
    if s % Q:
        # identity padding: log_f=0 keeps the state, log_i=-1e30 adds nothing
        pad = Q - s % Q
        pad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        pad3 = ((0, 0), (0, pad), (0, 0))
        h_out, st = _mlstm_chunked(
            jnp.pad(q, pad4), jnp.pad(k, pad4), jnp.pad(v, pad4),
            jnp.pad(log_i, pad3, constant_values=-1e30),
            jnp.pad(log_f, pad3), Q, C0, n0, m0,
        )
        return h_out[:, :s], st
    nc = s // Q
    scale = p ** -0.5

    qc = q.reshape(b, nc, Q, h, p).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(b, nc, Q, h, p).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nc, Q, h, p).transpose(1, 0, 2, 3, 4)
    lic = log_i.reshape(b, nc, Q, h).transpose(1, 0, 2, 3)
    lfc = log_f.reshape(b, nc, Q, h).transpose(1, 0, 2, 3)

    def step(carry, inp):
        C, n, m = carry  # [b,h,p,p], [b,h,p], [b,h]
        qq, kk, vv, li, lf = inp
        cumf = jnp.cumsum(lf, axis=1)  # [b,Q,h]
        total_f = cumf[:, -1]
        # log weights for source position j as seen at chunk end / position i
        # a_j = cumf_total - cumf_j + li_j   (state update weight)
        a = total_f[:, None, :] - cumf + li  # [b,Q,h]
        # b_i = cumf_i + m_prev  (inter-chunk read weight)
        b_read = cumf + m[:, None, :]
        # intra matrix: D[i,j] = cumf_i - cumf_j + li_j  (j<=i)
        Dm = cumf[:, :, None, :] - cumf[:, None, :, :] + li[:, None, :, :]
        tri = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
        Dm = jnp.where(tri, Dm, -jnp.inf)
        # stabilizer per target position i
        m_intra = jnp.max(Dm, axis=2)  # [b,Q,h]
        m_new_pos = jnp.maximum(m_intra, b_read)  # running stabilizer per i
        Dstab = jnp.exp(Dm - m_new_pos[:, :, None, :])
        inter_w = jnp.exp(b_read - m_new_pos)  # [b,Q,h]

        S = jnp.einsum("bihp,bjhp->bijh", qq, kk).astype(jnp.float32) * scale
        W = S * Dstab  # [b,i,j,h]
        h_intra = jnp.einsum("bijh,bjhp->bihp", W.astype(qq.dtype), vv)
        h_inter = jnp.einsum(
            "bihp,bhpo,bih->biho", qq.astype(jnp.float32), C, inter_w
        ).astype(qq.dtype) * scale
        denom_intra = jnp.einsum("bijh,bjhp->bihp", W.astype(qq.dtype), kk)
        # normalizer: n dot q
        denom_inter = jnp.einsum(
            "bihp,bhp,bih->bih", qq.astype(jnp.float32), n, inter_w
        ) * scale
        denom = jnp.abs(
            jnp.einsum("bihp,bihp->bih", qq.astype(jnp.float32),
                       denom_intra.astype(jnp.float32)) * scale
            + denom_inter
        )
        hh = (h_intra + h_inter) / jnp.maximum(
            denom, 1.0
        )[..., None].astype(qq.dtype)

        # state update (stabilized by m_next = max(total_f + m, max_j a_j))
        m_next = jnp.maximum(total_f + m, jnp.max(a, axis=1))
        wj = jnp.exp(a - m_next[:, None, :])  # [b,Q,h]
        C2 = C * jnp.exp(total_f + m - m_next)[:, :, None, None] + jnp.einsum(
            "bjhp,bjho,bjh->bhpo", kk.astype(jnp.float32),
            vv.astype(jnp.float32), wj,
        )
        n2 = n * jnp.exp(total_f + m - m_next)[:, :, None] + jnp.einsum(
            "bjhp,bjh->bhp", kk.astype(jnp.float32), wj
        )
        return (C2, n2, m_next), hh

    C0 = jnp.zeros((b, h, p, p), jnp.float32) if C0 is None else C0
    n0 = jnp.zeros((b, h, p), jnp.float32) if n0 is None else n0
    m0 = jnp.zeros((b, h), jnp.float32) if m0 is None else m0
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0),
                                 (qc, kc, vc, lic, lfc))
    hout = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return hout, (C, n, m)


def mlstm_block(cfg: ModelConfig, p, x, state=None, length=None):
    """mLSTM mixer. x: [b,s,d] -> (y, state).

    ``length`` (traced i32, None => s): positions >= length are right-pad.
    They are neutralized with the SAME identity trick ``_mlstm_chunked``
    uses for its own chunk padding — log_f=0 keeps the state, log_i=-1e30
    adds nothing — so the carried state and every valid position's output
    are exactly what an unpadded run produces (pad rows emit garbage that
    the caller must never read).
    """
    with scalpel.function("mlstm"):
        b, s, d = x.shape
        di = 2 * d
        nh = cfg.n_heads
        hd = di // nh
        up = jnp.einsum("bsd,de->bse", x, p["up"].astype(x.dtype))
        xb, z = jnp.split(up, 2, axis=-1)
        conv_state = state[3] if state is not None else None
        xc, conv_state = causal_conv1d(xb, p["conv_w"].astype(x.dtype),
                                       conv_state, length=length)
        xc = jax.nn.silu(
            (xc + p["conv_b"].astype(x.dtype)).astype(jnp.float32)
        ).astype(x.dtype)
        q = jnp.einsum("bse,ef->bsf", xc, p["wq"].astype(x.dtype))
        k = jnp.einsum("bse,ef->bsf", xc, p["wk"].astype(x.dtype))
        v = jnp.einsum("bse,ef->bsf", xb, p["wv"].astype(x.dtype))
        gates = jnp.einsum(
            "bse,eg->bsg", xc, p["w_if"].astype(x.dtype)
        ).astype(jnp.float32) + p["b_if"].astype(jnp.float32)
        li_pre, lf_pre = jnp.split(gates, 2, axis=-1)  # [b,s,nh]
        log_i = -jax.nn.softplus(-li_pre)   # log sigmoid
        log_f = -jax.nn.softplus(-lf_pre)
        if length is not None:
            valid = (jnp.arange(s) < length)[None, :, None]
            log_i = jnp.where(valid, log_i, -1e30)
            log_f = jnp.where(valid, log_f, 0.0)
        qh = q.reshape(b, s, nh, hd)
        kh = k.reshape(b, s, nh, hd)
        vh = v.reshape(b, s, nh, hd)
        qh = shard(qh, "batch", None, "heads", None)
        C0 = n0 = m0 = None
        if state is not None:
            C0, n0, m0 = state[0], state[1], state[2]
        h, (C, n, m) = _mlstm_chunked(
            qh, kh, vh, log_i, log_f, cfg.ssm.chunk, C0, n0, m0
        )
        scalpel.probe(state=C)
        from .layers import head_rms_norm

        h = head_rms_norm(h, jnp.ones((hd,), jnp.float32))
        h = h.reshape(b, s, di) * p["norm"].astype(x.dtype)
        h = h + xb * p["skip"].astype(x.dtype)
        h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
        y = jnp.einsum("bse,ed->bsd", h, p["down"].astype(x.dtype))
        y = shard(y, "batch", None, None)
        scalpel.probe(out=y)
        return y, (C, n, m, conv_state)


def mlstm_state_specs(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    di = 2 * d
    nh = cfg.n_heads
    hd = di // nh
    cdt = jnp.dtype(cfg.compute_dtype)
    return (
        jax.ShapeDtypeStruct((batch, nh, hd, hd), jnp.float32),
        jax.ShapeDtypeStruct((batch, nh, hd), jnp.float32),
        jax.ShapeDtypeStruct((batch, nh), jnp.float32),
        jax.ShapeDtypeStruct((batch, cfg.ssm.d_conv - 1, di), cdt),
    )


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, recurrent gates — sequential scan)
# ---------------------------------------------------------------------------

def slstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    f = int(d * 4 / 3) // 2 * 2
    return {
        "w": P((d, 4 * d), ("embed", "heads")),       # i,f,z,o pre-acts
        "r": P((nh, dh, 4 * dh), (None, None, None), scale=0.02),
        "b": P((4 * d,), (None,), init="zeros"),
        "norm": P((d,), ("embed",), init="ones"),
        "up_g": P((d, f), ("embed", "mlp")),
        "up_h": P((d, f), ("embed", "mlp")),
        "down": P((f, d), ("mlp", "embed")),
    }


def _slstm_cell(cfg: ModelConfig, p, wx, state):
    """One time step.  wx: [b, 4d] precomputed W@x_t; state tuple."""
    nh = cfg.n_heads
    d = cfg.d_model
    dh = d // nh
    c, n, hprev, m = state  # [b,nh,dh], [b,nh,dh], [b,nh,dh], [b,nh,dh]
    r = p["r"].astype(jnp.float32)  # [nh, dh, 4dh]
    rh = jnp.einsum("bhd,hdk->bhk", hprev, r)  # [b,nh,4dh]
    pre = wx.reshape(-1, nh, 4 * dh).astype(jnp.float32) + rh + \
        p["b"].astype(jnp.float32).reshape(nh, 4 * dh)
    ip, fp, zp, op = jnp.split(pre, 4, axis=-1)  # [b,nh,dh]
    # exponential gating with stabilizer m
    log_f = -jax.nn.softplus(-fp)  # log sigmoid(f)
    m_new = jnp.maximum(log_f + m, ip)
    i_g = jnp.exp(ip - m_new)
    f_g = jnp.exp(log_f + m - m_new)
    z_g = jnp.tanh(zp)
    o_g = jax.nn.sigmoid(op)
    c2 = f_g * c + i_g * z_g
    n2 = f_g * n + i_g
    h2 = o_g * (c2 / jnp.maximum(jnp.abs(n2), 1.0))
    return (c2, n2, h2, m_new), h2


def slstm_block(cfg: ModelConfig, p, x, state=None, length=None):
    """sLSTM mixer + gated FFN. x: [b,s,d] -> (y, state).

    ``length`` (traced i32, None => s): pad positions run identity scan
    steps — the cell computes but the carried state keeps its old value —
    so the recurrent state leaving the block is exactly the unpadded one.
    """
    with scalpel.function("slstm"):
        b, s, d = x.shape
        nh = cfg.n_heads
        dh = d // nh
        wx = jnp.einsum("bsd,dk->bsk", x, p["w"].astype(x.dtype))
        if state is None:
            z = jnp.zeros((b, nh, dh), jnp.float32)
            state = (z, z, z, z - 10.0)

        if length is None:
            def step(carry, wxt):
                return _slstm_cell(cfg, p, wxt, carry)

            state, hs = jax.lax.scan(step, state, wx.transpose(1, 0, 2))
        else:
            def step(carry, inp):
                wxt, keep = inp
                new, h2 = _slstm_cell(cfg, p, wxt, carry)
                new = jax.tree.map(
                    lambda a, o: jnp.where(keep, a, o), new, carry)
                return new, h2

            valid = jnp.arange(s) < length
            state, hs = jax.lax.scan(
                step, state, (wx.transpose(1, 0, 2), valid))
        h = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
        scalpel.probe(state=state[0])
        from .layers import rms_norm

        h = rms_norm(h, p["norm"])
        g = jnp.einsum("bsd,df->bsf", h, p["up_g"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", h, p["up_h"].astype(x.dtype))
        u = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * u
        y = jnp.einsum("bsf,fd->bsd", u, p["down"].astype(x.dtype))
        y = shard(y, "batch", None, None)
        scalpel.probe(out=y)
        return y, state


def slstm_state_specs(cfg: ModelConfig, batch: int):
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    sd = jax.ShapeDtypeStruct((batch, nh, dh), jnp.float32)
    return (sd, sd, sd, sd)
