"""Shared model layers, instrumented with ScALPEL scopes.

Every block opens a ``scalpel.function`` scope and probes its live tensors —
the analogue of compiling the application with ``-finstrument-functions``:
the *set* of monitorable functions is fixed by the model code, but whether
anything is computed for a scope is decided by the runtime MonitorParams
(mask) and the call-count multiplexer.

Attention has three execution paths:
  * ``reference``  — materialized probs (smoke tests; probes ATTN_ENTROPY)
  * ``flash_xla``  — chunked online-softmax in pure JAX (lax.scan over KV
                     blocks), bounded memory, TPU-lowerable; the dry-run path
  * ``flash_xla_tri`` — triangle-pair scan that skips fully-masked causal
                     blocks (≈2x fewer attention FLOPs; see §Perf)
  * ``pallas``     — kernels/flash_attn.py (real-TPU hot path)
Decode attention shards the KV cache along *sequence* over the model axis
(flash-decoding style); GSPMD inserts the small max/sum all-reduces.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro import core as scalpel
from repro.dist.partition import shard
from .params import P
from .spec import ModelConfig


def dt(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def remat_policy(cfg: ModelConfig):
    """Remat decorator per config — pass to scan_with_counters(remat=...)."""
    import functools

    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return functools.partial(
            jax.checkpoint, policy=jax.checkpoint_policies.checkpoint_dots
        )
    return jax.checkpoint


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm_spec(d: int) -> P:
    return P((d,), ("embed",), init="ones")


def rms_norm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(
        x.dtype
    )


def head_rms_norm(x, scale, eps: float = 1e-6):
    """qk-norm: normalize over head_dim (qwen3)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(
        x.dtype
    )


# ---------------------------------------------------------------------------
# rope
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: [..., s, h, d]; positions: [..., s] int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., s, half]
    cos = jnp.cos(ang)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_specs(cfg: ModelConfig) -> dict:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    sp = {
        "wq": P((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, k, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.use_bias:
        sp["bq"] = P((h, hd), ("heads", "head_dim"), init="zeros")
        sp["bo"] = P((d,), ("embed",), init="zeros")
    if cfg.qk_norm:
        sp["q_norm"] = P((hd,), ("head_dim",), init="ones")
        sp["k_norm"] = P((hd,), ("head_dim",), init="ones")
    return sp


def _qkv(cfg: ModelConfig, p, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.use_bias:
        q = q + p["bq"].astype(x.dtype)
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"])
        k = head_rms_norm(k, p["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    # heads that don't divide the TP axis are relaxed to replicated here;
    # run_attention() pads them to a shardable count before the mixing
    q = shard(q, "batch", None, "heads", None)
    return q, k, v


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(
        k[:, :, :, None, :], (b, s, h, n_rep, d)
    ).reshape(b, s, h * n_rep, d)


def reference_attention(cfg: ModelConfig, q, k, v, causal: bool = True,
                        window: int = 0):
    """Materialized-probs attention (smoke-scale only).  Probes entropy."""
    k = _repeat_kv(k, q.shape[2] // k.shape[2])
    v = _repeat_kv(v, q.shape[2] // v.shape[2])
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    sq, sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq)[:, None] + (sk - sq)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    scalpel.probe(probs=probs)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype), v)
    return out


def flash_attention_xla(cfg: ModelConfig, q, k, v, causal: bool = True,
                        window: int = 0, triangle: bool | None = None):
    """Chunked online-softmax attention, pure JAX (lowerable everywhere).

    ``triangle=True`` (default for causal self-attention): one scan over the
    (q_block, kv_block) lower-triangle pairs — exact causal FLOPs, O(1)
    graph size in sequence length.  ``triangle=False``: scan over all KV
    blocks for every Q block with masking (~2x causal FLOP waste; kept as
    the naive baseline measured in §Perf) — and the only path for
    non-square/non-causal attention.
    """
    if triangle is None:
        triangle = causal and q.shape[1] == k.shape[1]
    b, sq, h, d = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    n_rep = h // kvh
    bq = min(cfg.flash_block_q, sq)
    bkv = min(cfg.flash_block_kv, sk)
    nq = (sq + bq - 1) // bq
    nk = (sk + bkv - 1) // bkv
    assert sq % bq == 0 and sk % bkv == 0, (sq, bq, sk, bkv)
    scale = 1.0 / math.sqrt(d)
    offs = sk - sq  # query i attends keys <= i + offs

    qb = q.reshape(b, nq, bq, h, d)
    kb = k.reshape(b, nk, bkv, kvh, d)
    vb = v.reshape(b, nk, bkv, kvh, d)

    def block_scores(qi, kj, iq, jk):
        # qi: [b,bq,h,d] kj: [b,bkv,kvh,d] -> [b,h,bq,bkv]
        kj = _repeat_kv(kj, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, kj).astype(jnp.float32) * scale
        qpos = iq * bq + jnp.arange(bq)[:, None] + offs
        kpos = jk * bkv + jnp.arange(bkv)[None, :]
        m = jnp.ones((bq, bkv), bool)
        if causal:
            m &= kpos <= qpos
        if window:
            m &= kpos > qpos - window
        return jnp.where(m[None, None], s, -1e30)

    def one_q_block(iq, qi):
        def body(carry, jk):
            acc, mx, lse = carry
            kj = jax.lax.dynamic_index_in_dim(kb, jk, 1, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vb, jk, 1, keepdims=False)
            s = block_scores(qi, kj, iq, jk)  # [b,h,bq,bkv]
            mx2 = jnp.maximum(mx, jnp.max(s, axis=-1))
            corr = jnp.exp(mx - mx2)
            # guard fully-masked rows: exp(-1e30 - (-1e30)) would be 1
            p = jnp.exp(s - mx2[..., None]) * (s > -1e29)
            vj = _repeat_kv(vj, n_rep)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(q.dtype), vj)
            acc = acc * corr[..., None].astype(q.dtype) + pv
            lse = lse * corr + jnp.sum(p, axis=-1)
            return (acc, mx2, lse), None

        acc0 = jnp.zeros((b, h, bq, d), q.dtype)
        m0 = jnp.full((b, h, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, bq), jnp.float32)
        (acc, mx, lse), _ = jax.lax.scan(
            body, (acc0, m0, l0), jnp.arange(nk)
        )
        out = acc / jnp.maximum(lse, 1e-30)[..., None].astype(q.dtype)
        return out.transpose(0, 2, 1, 3)  # [b,bq,h,d]

    if triangle and causal and sq == sk:
        # lower-triangle pair scan: iterate (iq, jk<=iq) pairs once.
        pairs = [(i, j) for i in range(nq) for j in range(i + 1)]
        iqs = jnp.array([p[0] for p in pairs])
        jks = jnp.array([p[1] for p in pairs])

        def body(carry, t):
            acc, mx, lse, outbuf = carry
            iq, jk = iqs[t], jks[t]
            qi = jax.lax.dynamic_index_in_dim(qb, iq, 1, keepdims=False)
            kj = jax.lax.dynamic_index_in_dim(kb, jk, 1, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vb, jk, 1, keepdims=False)
            fresh = jk == 0
            acc = jnp.where(fresh, 0.0, acc)
            mx = jnp.where(fresh, -jnp.inf, mx)
            lse = jnp.where(fresh, 0.0, lse)
            s = block_scores(qi, kj, iq, jk)
            mx2 = jnp.maximum(mx, jnp.max(s, axis=-1))
            corr = jnp.exp(mx - mx2)
            p = jnp.exp(s - mx2[..., None]) * (s > -1e29)
            vjr = _repeat_kv(vj, n_rep)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(q.dtype), vjr)
            acc = acc * corr[..., None].astype(q.dtype) + pv
            lse = lse * corr + jnp.sum(p, axis=-1)
            done = jk == iq
            out = (acc / jnp.maximum(lse, 1e-30)[..., None].astype(q.dtype)
                   ).transpose(0, 2, 1, 3)
            outbuf = jnp.where(
                done,
                jax.lax.dynamic_update_index_in_dim(outbuf, out, iq, 1),
                outbuf,
            )
            return (acc, mx2, lse, outbuf), None

        acc0 = jnp.zeros((b, h, bq, d), q.dtype)
        m0 = jnp.full((b, h, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, bq), jnp.float32)
        ob0 = jnp.zeros((b, nq, bq, h, d), q.dtype)
        (_, _, _, outbuf), _ = jax.lax.scan(
            body, (acc0, m0, l0, ob0), jnp.arange(len(pairs))
        )
        return outbuf.reshape(b, sq, h, d)

    # masked path: scan over q blocks, full kv scan inside (O(1) graph size)
    def outer(_, iq):
        qi = jax.lax.dynamic_index_in_dim(qb, iq, 1, keepdims=False)
        return None, one_q_block(iq, qi)

    _, outs = jax.lax.scan(outer, None, jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)
    return out


def run_attention(cfg: ModelConfig, q, k, v, causal: bool = True,
                  window: int = 0):
    """Dispatch the sequence-mixing implementation, with head padding.

    When n_heads does not divide the TP axis (qwen3-14b: 40 heads on a
    16-way model axis) the heads are PADDED to the next multiple so the
    attention itself stays head-sharded — +hpad/h extra attention work vs
    the tp-times redundant replicated fallback it replaces (EXPERIMENTS.md
    §Perf, qwen3_14b iteration).
    """
    from repro.dist.partition import axis_size

    impl = cfg.attn_impl
    sq = q.shape[1]
    if impl == "reference" or sq <= 256:
        return reference_attention(cfg, q, k, v, causal, window)

    tp = axis_size("model")
    h = q.shape[2]
    hpad = -(-h // tp) * tp if tp > 1 else h
    sliced = False
    if hpad != h:
        n_rep = h // k.shape[2]
        k = _repeat_kv(k, n_rep)
        v = _repeat_kv(v, n_rep)
        padh = ((0, 0), (0, 0), (0, hpad - h), (0, 0))
        q = jnp.pad(q, padh)
        k = jnp.pad(k, padh)
        v = jnp.pad(v, padh)
        q = shard(q, "batch", None, "heads", None)
        k = shard(k, "batch", None, "heads", None)
        v = shard(v, "batch", None, "heads", None)
        sliced = True

    if impl == "pallas":
        from repro.kernels import ops as kops

        out = kops.flash_attention(
            q, k, v, causal=causal,
            block_q=cfg.flash_block_q, block_kv=cfg.flash_block_kv,
        )
    elif impl == "flash_xla_naive":
        out = flash_attention_xla(cfg, q, k, v, causal, window,
                                  triangle=False)
    elif impl == "flash_xla_tri":
        out = flash_attention_xla(cfg, q, k, v, causal, window,
                                  triangle=True)
    else:  # "flash_xla" and default: custom-VJP memory-optimal path
        out = flash_attention_cvjp(cfg, q, k, v, causal, window)
    if sliced:
        out = out[:, :, :h]
    return out


def attention(cfg: ModelConfig, p, x, positions, causal: bool = True,
              window: int = 0):
    """Full attention block: projections + mixing + output projection."""
    with scalpel.function("attn"):
        q, k, v = _qkv(cfg, p, x, positions)
        scalpel.probe(q=q, k=k, v=v)
        out = run_attention(cfg, q, k, v, causal, window)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
        if cfg.use_bias:
            y = y + p["bo"].astype(x.dtype)
        y = shard(y, "batch", None, None)
        scalpel.probe(out=y)
        return y


def decode_attention(cfg: ModelConfig, p, x, cache_k, cache_v, pos):
    """One-token decode against a sequence-sharded KV cache.

    x: [b, 1, d]; cache_{k,v}: [b, S, kv, hd] with S sharded over 'model'
    (flash-decoding-style sequence parallelism — GSPMD inserts the small
    softmax all-reduces); ``pos`` scalar int32 — write position of the new
    token (uniform across the batch, standard static-batch serving).
    Returns (y [b,1,d], cache_k', cache_v').
    """
    with scalpel.function("attn"):
        b = x.shape[0]
        positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
        q, k_new, v_new = _qkv(cfg, p, x, positions)
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k_new.astype(cache_k.dtype), pos, axis=1
        )
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v_new.astype(cache_v.dtype), pos, axis=1
        )
        cache_k = shard(cache_k, "batch", "kv_seq", None, None)
        cache_v = shard(cache_v, "batch", "kv_seq", None, None)
        n_rep = cfg.n_heads // cfg.n_kv_heads
        kr = _repeat_kv(cache_k.astype(x.dtype), n_rep)  # [b,S,h,hd]
        vr = _repeat_kv(cache_v.astype(x.dtype), n_rep)
        scale = 1.0 / math.sqrt(q.shape[-1])
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kr).astype(jnp.float32) * scale
        S = cache_k.shape[1]
        kpos = jnp.arange(S)[None, None, None, :]
        valid = kpos <= pos
        if cfg.sliding_window:
            valid = valid & (kpos > pos - cfg.sliding_window)
        s = jnp.where(valid, s, -1e30)
        p_attn = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", p_attn.astype(x.dtype), vr)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
        if cfg.use_bias:
            y = y + p["bo"].astype(x.dtype)
        scalpel.probe(out=y)
        return y, cache_k, cache_v


# ---------------------------------------------------------------------------
# flash attention v2: custom-VJP (memory-optimal backward)
#
# The scan-based flash_attention_xla above is exact but its reverse-mode
# stores every block's probability tile across the pair scan — the dry-run
# breakdown showed stacked f32[n_pairs, b, h, bq, bkv] residual buffers
# dominating the memory roofline term (EXPERIMENTS.md §Perf, memory
# iteration).  This version severs the residual chain with jax.custom_vjp:
# the forward saves only (q, k, v, out, lse) and the backward recomputes
# probability tiles blockwise — the standard flash-attention backward,
# expressed in pure JAX so it lowers everywhere (Pallas kernels/flash_attn
# is the real-TPU fast path of the same algorithm).
# ---------------------------------------------------------------------------

def _fa_blocks(x, blk):
    b, s, h, d = x.shape
    return x.reshape(b, s // blk, blk, h, d)


def _tile_mask(iq, jk, bq, bkv, offs, causal, window, sk):
    qpos = iq * bq + jnp.arange(bq)[:, None] + offs
    kpos = jk * bkv + jnp.arange(bkv)[None, :]
    m = kpos < sk
    if causal:
        m &= kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m


def _tile_live(iq, jk, bq, bkv, offs, causal, window):
    live = jnp.bool_(True)
    if causal:
        live &= (jk * bkv) <= (iq * bq + bq - 1 + offs)
    if window:
        live &= (jk * bkv + bkv - 1) > (iq * bq + offs - window)
    return live


def _flash_fwd_scan(q, k, v, causal, window, bq, bkv, scale):
    """Returns (out [b,sq,h,d], lse [b,h,sq//bq,bq]) — q-block outer scan
    (stacked outputs, no growing carry), kv-block inner scan with tile
    skipping."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    offs = sk - sq
    qb = _fa_blocks(q, bq)
    kb = _fa_blocks(k, bkv)
    vb = _fa_blocks(v, bkv)
    nq, nk = sq // bq, sk // bkv

    def q_block(_, iq):
        qi = jax.lax.dynamic_index_in_dim(qb, iq, 1, False)  # [b,bq,h,d]
        qi_f = qi.astype(jnp.float32)

        def kv_step(carry, jk):
            acc, mx, lse = carry

            def work(args):
                acc, mx, lse = args
                kj = jax.lax.dynamic_index_in_dim(kb, jk, 1, False)
                vj = jax.lax.dynamic_index_in_dim(vb, jk, 1, False)
                s = jnp.einsum("bqhd,bkhd->bhqk", qi_f,
                               kj.astype(jnp.float32)) * scale
                m = _tile_mask(iq, jk, bq, bkv, offs, causal, window, sk)
                s = jnp.where(m[None, None], s, -1e30)
                mx2 = jnp.maximum(mx, jnp.max(s, axis=-1))
                corr = jnp.exp(mx - mx2)
                p = jnp.exp(s - mx2[..., None])
                p = jnp.where(m[None, None], p, 0.0)
                pv = jnp.einsum("bhqk,bkhd->bhqd", p,
                                vj.astype(jnp.float32))
                return (acc * corr[..., None] + pv,
                        mx2, lse * corr + jnp.sum(p, axis=-1))

            return jax.lax.cond(
                _tile_live(iq, jk, bq, bkv, offs, causal, window),
                work, lambda a: a, (acc, mx, lse),
            ), None

        acc0 = jnp.zeros((b, h, bq, d), jnp.float32)
        m0 = jnp.full((b, h, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, bq), jnp.float32)
        (acc, mx, lse), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                         jnp.arange(nk))
        out = (acc / jnp.maximum(lse, 1e-30)[..., None]).transpose(0, 2, 1, 3)
        # signed lse for the backward: log(sum exp(s - 0)) = mx + log(lse)
        lse_log = mx + jnp.log(jnp.maximum(lse, 1e-30))
        return None, (out.astype(q.dtype), lse_log)

    _, (outs, lses) = jax.lax.scan(q_block, None, jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)
    return out, lses.transpose(1, 2, 0, 3)  # [b,h,nq,bq]


def _flash_bwd_scan(res, dout, causal, window, bq, bkv, scale):
    q, k, v, out, lse = res          # lse: [b,h,nq,bq]
    b, sq, h, d = q.shape
    sk = k.shape[1]
    offs = sk - sq
    qb = _fa_blocks(q, bq)
    kb = _fa_blocks(k, bkv)
    vb = _fa_blocks(v, bkv)
    dob = _fa_blocks(dout.astype(jnp.float32), bq)
    ob = _fa_blocks(out.astype(jnp.float32), bq)
    nq, nk = sq // bq, sk // bkv
    # D_i = rowsum(dO * O)  [b,nq,bq,h] -> [b,h,nq,bq]
    Dfull = jnp.sum(dob * ob, axis=-1).transpose(0, 3, 1, 2)

    def p_tile(iq, jk):
        qi = jax.lax.dynamic_index_in_dim(qb, iq, 1, False)
        kj = jax.lax.dynamic_index_in_dim(kb, jk, 1, False)
        s = jnp.einsum("bqhd,bkhd->bhqk", qi.astype(jnp.float32),
                       kj.astype(jnp.float32)) * scale
        m = _tile_mask(iq, jk, bq, bkv, offs, causal, window, sk)
        s = jnp.where(m[None, None], s, -1e30)
        lse_i = jax.lax.dynamic_index_in_dim(lse, iq, 2, False)  # [b,h,bq]
        p = jnp.exp(s - lse_i[..., None])
        p = jnp.where(m[None, None], p, 0.0)
        return p, qi, kj

    # ---- dq: scan over q blocks, inner over kv ---------------------------
    def dq_block(_, iq):
        doi = jax.lax.dynamic_index_in_dim(dob, iq, 1, False)  # [b,bq,h,d]
        doi_t = doi.transpose(0, 2, 1, 3)                      # [b,h,bq,d]
        Di = jax.lax.dynamic_index_in_dim(Dfull, iq, 2, False)  # [b,h,bq]

        def kv_step(dq, jk):
            def work(dq):
                p, qi, kj = p_tile(iq, jk)
                dp = jnp.einsum("bhqd,bkhd->bhqk", doi_t,
                                jax.lax.dynamic_index_in_dim(
                                    vb, jk, 1, False).astype(jnp.float32))
                ds = p * (dp - Di[..., None]) * scale
                return dq + jnp.einsum("bhqk,bkhd->bqhd", ds,
                                       kj.astype(jnp.float32))

            return jax.lax.cond(
                _tile_live(iq, jk, bq, bkv, offs, causal, window),
                work, lambda x: x, dq,
            ), None

        dq0 = jnp.zeros((b, bq, h, d), jnp.float32)
        dqi, _ = jax.lax.scan(kv_step, dq0, jnp.arange(nk))
        return None, dqi

    _, dqs = jax.lax.scan(dq_block, None, jnp.arange(nq))
    dq = dqs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)

    # ---- dk/dv: scan over kv blocks, inner over q -------------------------
    def dkv_block(_, jk):
        vj = jax.lax.dynamic_index_in_dim(vb, jk, 1, False)

        def q_step(carry, iq):
            dk_j, dv_j = carry

            def work(args):
                dk_j, dv_j = args
                p, qi, kj = p_tile(iq, jk)
                doi = jax.lax.dynamic_index_in_dim(
                    dob, iq, 1, False).transpose(0, 2, 1, 3)
                Di = jax.lax.dynamic_index_in_dim(Dfull, iq, 2, False)
                dv_j = dv_j + jnp.einsum("bhqk,bhqd->bkhd", p, doi)
                dp = jnp.einsum("bhqd,bkhd->bhqk", doi,
                                vj.astype(jnp.float32))
                ds = p * (dp - Di[..., None]) * scale
                dk_j = dk_j + jnp.einsum("bhqk,bqhd->bkhd", ds,
                                         qi.astype(jnp.float32))
                return dk_j, dv_j

            return jax.lax.cond(
                _tile_live(iq, jk, bq, bkv, offs, causal, window),
                work, lambda a: a, (dk_j, dv_j),
            ), None

        z = jnp.zeros((b, bkv, h, d), jnp.float32)
        (dk_j, dv_j), _ = jax.lax.scan(q_step, (z, z), jnp.arange(nq))
        return None, (dk_j, dv_j)

    _, (dks, dvs) = jax.lax.scan(dkv_block, None, jnp.arange(nk))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, sk, h, d)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, sk, h, d)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_cvjp(q, k, v, causal, window, bq, bkv, scale):
    out, _ = _flash_fwd_scan(q, k, v, causal, window, bq, bkv, scale)
    return out


def _flash_cvjp_fwd(q, k, v, causal, window, bq, bkv, scale):
    out, lse = _flash_fwd_scan(q, k, v, causal, window, bq, bkv, scale)
    return out, (q, k, v, out, lse)


def _flash_cvjp_bwd(causal, window, bq, bkv, scale, res, dout):
    return _flash_bwd_scan(res, dout, causal, window, bq, bkv, scale)


_flash_cvjp.defvjp(_flash_cvjp_fwd, _flash_cvjp_bwd)


def flash_attention_cvjp(cfg: ModelConfig, q, k, v, causal: bool = True,
                         window: int = 0):
    """Flash attention with the memory-optimal custom-VJP backward.

    GQA is handled by repeating KV up front (the repeat is elementwise and
    fuses; the backward sums gradient over the repeat groups).
    """
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    n_rep = h // kvh
    if n_rep > 1:
        k = _repeat_kv(k, n_rep)
        v = _repeat_kv(v, n_rep)
    sk = k.shape[1]
    bq = min(cfg.flash_block_q, sq)
    bkv = min(cfg.flash_block_kv, sk)
    assert sq % bq == 0 and sk % bkv == 0, (sq, bq, sk, bkv)
    out = _flash_cvjp(q, k, v, causal, window, bq, bkv,
                      1.0 / math.sqrt(d))
    return out


# ---------------------------------------------------------------------------
# mlp
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wi": P((d, f), ("embed", "mlp")),
        "wg": P((d, f), ("embed", "mlp")),
        "wo": P((f, d), ("mlp", "embed")),
    }


def mlp(cfg: ModelConfig, p, x):
    with scalpel.function("mlp"):
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
        h = shard(h, "batch", None, "mlp")
        y = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))
        y = shard(y, "batch", None, None)
        scalpel.probe(out=y)
        return y


# ---------------------------------------------------------------------------
# embedding / unembedding / loss
# ---------------------------------------------------------------------------

def embed_specs(cfg: ModelConfig) -> dict:
    # std 0.02 (GPT-2 convention) keeps tied-unembedding logits at a sane
    # scale: rms_norm output has unit per-dim RMS, so logit std ~ 0.02*sqrt(d).
    sp = {"table": P((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                     scale=0.02)}
    if not cfg.tie_embeddings:
        sp["unembed"] = P((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return sp


def embed(cfg: ModelConfig, p, tokens):
    with scalpel.function("embed"):
        x = jnp.take(p["table"].astype(dt(cfg)), tokens, axis=0)
        x = shard(x, "batch", None, None)
        scalpel.probe(out=x)
        return x


def unembed(cfg: ModelConfig, p, x):
    with scalpel.function("logits"):
        if cfg.tie_embeddings:
            w = p["table"].astype(x.dtype).T
        else:
            w = p["unembed"].astype(x.dtype)
        logits = jnp.einsum("bsd,dv->bsv", x, w)
        logits = shard(logits, "batch", None, "vocab")
        scalpel.probe(out=logits)
        return logits


def cross_entropy(logits, targets, mask=None):
    """logits [b,s,V] (possibly vocab-sharded), targets [b,s] int32."""
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    ll = jnp.sum(
        lf * jax.nn.one_hot(targets, lf.shape[-1], dtype=jnp.float32),
        axis=-1,
    )
    nll = lse - ll
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    with scalpel.function("loss"):
        scalpel.probe(loss=loss[None])
    return loss
