"""xLSTM language model: alternating mLSTM / sLSTM blocks (family 'ssm').

Blocks are scanned in (mLSTM, sLSTM) pairs with stacked params; d_ff=0 in
the assigned config — the cells carry their own up/down projections.
Sub-quadratic: runs the long_500k decode cell with O(1) recurrent state.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro import core as scalpel
from . import layers as L
from . import ssm
from .params import stacked
from .spec import ModelConfig


# bucketed serving: prefill accepts a traced ``length`` with right-padded
# tokens (mask-correct gates/conv/scan — see ssm.py) so one compiled
# program serves a whole prompt-length bucket
SUPPORTS_PREFILL_LENGTH = True


def _n_pairs(cfg: ModelConfig) -> int:
    assert cfg.n_layers % 2 == 0, "xlstm stack scans (mLSTM, sLSTM) pairs"
    return cfg.n_layers // 2


def specs(cfg: ModelConfig) -> dict:
    n = _n_pairs(cfg)
    return {
        "embed": L.embed_specs(cfg),
        "pairs": stacked(
            lambda: {
                "m_ln": L.rms_norm_spec(cfg.d_model),
                "m": ssm.mlstm_specs(cfg),
                "s_ln": L.rms_norm_spec(cfg.d_model),
                "s": ssm.slstm_specs(cfg),
            },
            n,
        ),
        "final_norm": L.rms_norm_spec(cfg.d_model),
    }


def _pair(cfg: ModelConfig, lp, x, m_state=None, s_state=None, length=None):
    with scalpel.function("layer"):
        h = L.rms_norm(x, lp["m_ln"])
        y, m_state = ssm.mlstm_block(cfg, lp["m"], h, m_state, length=length)
        x = x + y
        h = L.rms_norm(x, lp["s_ln"])
        y, s_state = ssm.slstm_block(cfg, lp["s"], h, s_state, length=length)
        x = x + y
    return x, (m_state, s_state)


def forward(cfg: ModelConfig, params, tokens, prefix_embeds=None):
    x = L.embed(cfg, params["embed"], tokens)

    def body(carry, lp):
        out, _ = _pair(cfg, lp, carry)
        return out, None

    x, _ = scalpel.scan_with_counters(body, x, params["pairs"],
                                      remat=L.remat_policy(cfg))
    x = L.rms_norm(x, params["final_norm"])
    return L.unembed(cfg, params["embed"], x)


def loss_fn(cfg: ModelConfig, params, batch):
    logits = forward(cfg, params, batch["tokens"])
    return L.cross_entropy(logits, batch["targets"], batch.get("mask"))


# -- serving ---------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               abstract: bool = False):
    """Recurrent 'cache' = per-pair (mLSTM state, sLSTM state); no KV."""
    del cache_len  # O(1) state — the point of the ssm family
    n = _n_pairs(cfg)

    def stack_sds(sds):
        return jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct((n,) + sd.shape, sd.dtype), sds
        )

    m = stack_sds(ssm.mlstm_state_specs(cfg, batch))
    s = stack_sds(ssm.slstm_state_specs(cfg, batch))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    out = {"m": m, "s": s, "pos": pos}
    if abstract:
        return out
    return jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype), out,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def cache_axes(cfg: ModelConfig):
    m = (
        ("layers", "batch", "heads", None, None),
        ("layers", "batch", "heads", None),
        ("layers", "batch", "heads"),
        ("layers", "batch", None, None),
    )
    s = tuple(("layers", "batch", "heads", None) for _ in range(4))
    return {"m": m, "s": s, "pos": ()}


def prefill(cfg: ModelConfig, params, tokens, cache_len: int,
            prefix_embeds=None, length=None):
    """Run the prompt once, carrying recurrent states into the cache.

    ``length`` (traced i32, None => full width): tokens beyond it are
    right-pad — the recurrent states ignore them (identity steps) and the
    logits are read at position ``length - 1``, so ONE compiled program
    serves every prompt length in a bucket.
    """
    x = L.embed(cfg, params["embed"], tokens)

    def body(carry, lp):
        out, (m_state, s_state) = _pair(cfg, lp, carry, length=length)
        return out, (m_state, s_state)

    x, states = scalpel.scan_with_counters(body, x, params["pairs"])
    m_states, s_states = states
    x = L.rms_norm(x, params["final_norm"])
    if length is None:
        xl = x[:, -1:, :]
        pos = jnp.asarray(tokens.shape[1], jnp.int32)
    else:
        xl = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)
        pos = jnp.asarray(length, jnp.int32)
    logits = L.unembed(cfg, params["embed"], xl)
    cache = {"m": m_states, "s": s_states, "pos": pos}
    return cache, logits


def decode_step(cfg: ModelConfig, params, cache, tokens):
    x = L.embed(cfg, params["embed"], tokens)

    def body(carry, layer_in):
        lp, m_state, s_state = layer_in
        out, (m2, s2) = _pair(cfg, lp, carry, m_state, s_state)
        return out, (m2, s2)

    x, (m2, s2) = scalpel.scan_with_counters(
        body, x, (params["pairs"], cache["m"], cache["s"])
    )
    x = L.rms_norm(x, params["final_norm"])
    logits = L.unembed(cfg, params["embed"], x)
    return logits, {"m": m2, "s": s2, "pos": cache["pos"] + 1}
